"""Activation-aware calibration: per-tile absmax statistics → class maps.

The per-tile symmetric-absmax integer formats (``int8_pt``/``int4_pt``)
spend one scale per tile, so their quantization error on a K-block of a
weight is ``u_q · absmax(block)`` — *independent of the activations that
multiply it*.  But the forward error it induces is not: a block whose
input channels carry loud activations amplifies its weight rounding by
the activation magnitude (the AWQ observation).  Calibration therefore
scores each K-block by

    score(block) = max_{k ∈ block}  act_absmax[k] · absmax(W[k, :])

and assigns the top ``ratio_high`` fraction of blocks to the HIGH role
(kept in the float format) while the quiet remainder drops to the integer
low role.  The sort is a stable argsort over ``-scores``, so equal-score
ties break by block index and the resulting map is a pure function of
(weights, stats, ratio) — deterministic across processes, which keeps the
plan-cache keys and serve warmup stable.

Statistics are collected *online*: :class:`ActStats` folds per-channel
absmax over any number of observed activation batches, keyed by channel
dimension (every ksplit weight with ``K == dim`` consumes the same
residual-stream statistics).  ``quantize_params`` then rebuilds every
:class:`~repro.core.layout.KSplitWeight` leaf of a parameter tree under
an int-containing :class:`~repro.core.formats.FormatSet` with the
calibrated map — the output is an ordinary params pytree, served through
``Engine(..., variants={tag: qparams})`` with zero extra machinery.

NSplit weights fold data-driven column permutations into the *next*
layer at init time, so re-mapping them post hoc would break that
contract; they (and plain dense arrays) pass through unchanged.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.formats import DEFAULT_FORMATS, FormatSet
from repro.core.layout import KSplitWeight, NSplitWeight


def activation_absmax(x) -> np.ndarray:
    """Per-channel absmax of one activation batch ``[..., K] → [K]``."""
    xa = np.abs(np.asarray(x, np.float32))
    return xa.reshape(-1, xa.shape[-1]).max(axis=0)


@dataclasses.dataclass
class ActStats:
    """Online per-channel activation absmax, keyed by channel dimension.

    ``observe(x)`` folds a batch in (running elementwise max); ``get(k)``
    returns the ``[k]`` absmax vector, or all-ones when dimension ``k``
    was never observed (calibration then degrades to weight-only scores).
    """

    by_dim: dict = dataclasses.field(default_factory=dict)

    def observe(self, x) -> "ActStats":
        am = activation_absmax(x)
        k = am.shape[0]
        prev = self.by_dim.get(k)
        self.by_dim[k] = am if prev is None else np.maximum(prev, am)
        return self

    def get(self, k: int) -> np.ndarray:
        am = self.by_dim.get(k)
        return np.ones(k, np.float32) if am is None else am


def block_scores(w, act_amax: np.ndarray, tile: int) -> np.ndarray:
    """Loudness score per K-block of ``W[K, N]``:
    ``max_k act_absmax[k]·absmax(W[k,:])`` within each block (fp32)."""
    wa = np.abs(np.asarray(w, np.float32))
    k = wa.shape[0]
    assert k % tile == 0, (k, tile)
    row = wa.max(axis=1) * np.asarray(act_amax, np.float32)[:k]
    return row.reshape(k // tile, tile).max(axis=1)


def calibrated_cls(scores: np.ndarray, ratio_high: float,
                   fset: FormatSet) -> np.ndarray:
    """Class vector from block scores: top ``ratio_high`` fraction HIGH,
    the rest the set's LOW role.  Stable argsort → deterministic map."""
    nb = scores.shape[0]
    n_hi = int(round(float(ratio_high) * nb))
    cls = np.full(nb, fset.low, np.int8)
    order = np.argsort(-np.asarray(scores, np.float64), kind="stable")
    cls[order[:n_hi]] = fset.high
    return cls


def calibrate_ksplit(w: KSplitWeight, act_amax: np.ndarray,
                     fset: FormatSet, ratio_high: float) -> KSplitWeight:
    """Re-encode one ksplit weight under ``fset`` with the activation-aware
    map.  The dense weight is reconstructed from the current buffers (so
    calibration composes with whatever storage rounding already happened).

    Scan-stacked weights (buffers carrying a leading layer dim, the aux
    data shared) get ONE map for the whole stack — the class map is static
    metadata every scanned layer must agree on — scored by the worst layer
    per block (max over the stack)."""
    stacked = max(b.ndim for b in w.bufs) == 3
    layers = [w] if not stacked else [
        KSplitWeight(tuple(b[layer] for b in w.bufs), w.k_cls, w.tile,
                     w.shape, w.fset)
        for layer in range(max(b.shape[0] for b in w.bufs if b.ndim == 3))]
    denses = [lw.to_dense() for lw in layers]
    scores = np.max([block_scores(d, act_amax, w.tile) for d in denses],
                    axis=0)
    cls = calibrated_cls(scores, ratio_high, fset)
    rebuilt = [KSplitWeight.from_dense(d, cls, w.tile, fset) for d in denses]
    if not stacked:
        return rebuilt[0]
    bufs = tuple(jnp.stack([r.bufs[code] for r in rebuilt])
                 for code in fset.codes)
    return KSplitWeight(bufs, rebuilt[0].k_cls, w.tile, w.shape, fset)


def quantize_params(params, stats: ActStats | None = None, *,
                    fset: FormatSet | None = None,
                    ratio_high: float = 0.25):
    """Activation-aware quantized variant of a parameter tree.

    Every :class:`KSplitWeight` leaf is rebuilt under ``fset`` (default:
    ``int8_pt`` replacing the LOW role of the repo default set) with the
    calibrated class map; NSplit and dense leaves pass through unchanged.
    Returns a params pytree suitable for ``Engine(variants={tag: ...})``.
    """
    from repro.core.formats import format_set
    if fset is None:
        fset = format_set("int8_pt", DEFAULT_FORMATS.names[-1])
    stats = stats or ActStats()

    def leaf(x):
        if isinstance(x, KSplitWeight):
            return calibrate_ksplit(x, stats.get(x.shape[0]), fset,
                                    ratio_high)
        return x

    return jax.tree_util.tree_map(
        leaf, params,
        is_leaf=lambda x: isinstance(x, (KSplitWeight, NSplitWeight)))


def map_report(w: KSplitWeight) -> dict:
    """Bytes + class-mix summary of one calibrated weight.

    Storage is derived from the class map (``tile_bytes`` per tile, scale
    metadata included), which stays exact for scan-stacked weights where
    the raw buffer shapes carry a leading layer dimension."""
    k, n = w.shape
    cls = np.asarray(w.k_cls.arr)
    layers = max((b.shape[0] for b in w.bufs if b.ndim == 3), default=1)
    per_layer = sum((int(n) // w.tile) * w.fset.tile_bytes(int(c), w.tile)
                    for c in cls)
    dense = layers * int(k) * int(n) * 4
    return {
        "shape": (int(k), int(n)),
        "layers": int(layers),
        "classes": {w.fset.names[c]: int((cls == c).sum())
                    for c in np.unique(cls)},
        "storage_bytes": int(layers * per_layer),
        "bytes_vs_fp32": float(layers * per_layer) / dense,
    }


__all__ = [
    "ActStats", "activation_absmax", "block_scores", "calibrate_ksplit",
    "calibrated_cls", "map_report", "quantize_params",
]
