"""Training loop: jitted step + prefetch + async checkpoint + watchdog.

The loop is restart-safe: on ``RestartSignal`` (straggler/failure, possibly
injected by tests) it restores the latest checkpoint — optionally onto a
shrunken mesh — and resumes from the saved step with the deterministic data
pipeline replaying the exact stream.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.configs.base import ArchConfig
from repro.data.pipeline import Prefetcher, make_batch
from repro.models import transformer as T
from repro.optim import adamw
from repro.runtime.fault import Heartbeat, RestartSignal, Watchdog
from repro.train.train_step import make_train_step


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    seq_len: int = 128
    global_batch: int = 8
    microbatches: int = 1
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    log_every: int = 10
    seed: int = 0
    heartbeat_path: str = ""
    fault_injector: Optional[Callable[[int], None]] = None  # tests


def train(cfg: ArchConfig, ocfg: adamw.AdamWConfig, tcfg: TrainerConfig,
          *, params=None, opt_state=None, start_step: int = 0,
          log: Callable[[str], None] = print, _history=None):
    """Returns (params, opt_state, history)."""
    if params is None:
        params = T.init_model(jax.random.PRNGKey(tcfg.seed), cfg)
    if opt_state is None:
        opt_state = adamw.init(params, ocfg)

    step_fn = jax.jit(make_train_step(
        cfg, ocfg, tcfg.microbatches, tune_params=params,
        tune_tokens=tcfg.seq_len * tcfg.global_batch // tcfg.microbatches))
    saver = ckpt.AsyncCheckpointer(tcfg.ckpt_dir)
    hb = Heartbeat(tcfg.heartbeat_path) if tcfg.heartbeat_path else None
    wd = Watchdog()
    history = _history if _history is not None else []

    pf = Prefetcher(cfg, tcfg.seq_len, tcfg.global_batch, kind="train",
                    seed=tcfg.seed, start_step=start_step)
    it = iter(pf)
    step = start_step
    try:
        while step < tcfg.steps:
            got_step, batch = next(it)
            assert got_step == step, (got_step, step)
            t0 = time.monotonic()
            try:
                if tcfg.fault_injector is not None:
                    tcfg.fault_injector(step)
                params, opt_state, metrics = step_fn(params, opt_state,
                                                     batch)
                loss = float(metrics["loss"])
            except RestartSignal as e:
                log(f"[fault] step {step}: {e.reason} → restore+resume")
                pf.close()
                return _recover(cfg, ocfg, tcfg, saver, e, params, opt_state,
                                step, log, history)
            dt = time.monotonic() - t0
            wd.record(dt)
            if hb:
                hb.beat(step, dt)
            fault = wd.check()
            if fault and "straggler" in fault:
                log(f"[watchdog] {fault}")
            if step % tcfg.log_every == 0:
                log(f"step {step:5d} loss {loss:.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")
            history.append({"step": step, "loss": loss, "time": dt})
            step += 1
            if step % tcfg.ckpt_every == 0 or step == tcfg.steps:
                saver.submit({"params": params, "opt": opt_state}, step)
    finally:
        pf.close()
    saver.wait()
    return params, opt_state, history


def _recover(cfg, ocfg, tcfg, saver, sig: RestartSignal, params, opt_state,
             step, log, history):
    """Restore from the newest checkpoint and resume (recursion-safe since
    the injector is consumed by clearing it for replayed steps)."""
    saver.wait()
    latest = saver.latest()
    if latest is None:
        log("[fault] no checkpoint yet → restart from step 0 state")
        restored = {"params": params, "opt": opt_state}
        resume_step = 0
    else:
        restored, manifest = ckpt.restore(latest,
                                          {"params": params,
                                           "opt": opt_state})
        resume_step = manifest["step"]
        log(f"[fault] restored step {resume_step} from {latest}")
    # clear the injector for steps already survived (prevents fault loops)
    inj = tcfg.fault_injector
    tcfg2 = dataclasses.replace(
        tcfg, fault_injector=(lambda s: None if s <= step else inj(s))
        if inj else None)
    # drop replayed history entries so the merged record is per-step unique
    kept = [h for h in history if h["step"] < resume_step]
    return train(cfg, ocfg, tcfg2, params=restored["params"],
                 opt_state=restored["opt"], start_step=resume_step, log=log,
                 _history=kept)
