"""Training step: microbatched grad accumulation + AdamW + metrics.

``make_train_step(cfg, ocfg, microbatches)`` builds the pure function the
trainer jits (and the dry-run lowers on the production mesh).  Microbatches
split the per-step batch along batch dim and accumulate gradients in a bf16
accumulator with error feedback (optim.grad_compress) — sequential scan, so
peak activation memory is one microbatch.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim import adamw
from repro.optim import grad_compress as GC


def loss_fn(params, cfg: ArchConfig, batch):
    loss, metrics = T.forward_train(params, cfg, batch)
    return loss, metrics


def make_train_step(cfg: ArchConfig, ocfg: adamw.AdamWConfig,
                    microbatches: int = 1, compress_accum: bool = True,
                    tune_params=None, tune_tokens: int | None = None):
    """``tune_params``: pass the (or a same-shaped) parameter tree to
    tune-once at setup — every MPLinear's GEMM plan is resolved against the
    per-microbatch token count *before* the step is jitted, so dispatch
    decisions are fixed and identical across recompilations."""
    from repro import obs
    if tune_params is not None:
        from repro.tune import dispatch as _tune
        with obs.span("train.tune_setup", "train",
                      m_hint=tune_tokens or 4096):
            _tune.warm_registry()
            _tune.tune_linear_params(tune_params,
                                     m_hint=tune_tokens or 4096)
    if obs.is_enabled():
        obs.event("train.step_config", "train", microbatches=microbatches,
                  compress_accum=compress_accum,
                  tuned=tune_params is not None)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, cfg, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape(microbatches, b // microbatches,
                                 *x.shape[1:])
            micro = jax.tree.map(split, batch)

            def micro_step(carry, mb):
                acc, err, loss_sum = carry
                (loss, _), grads = grad_fn(params, cfg, mb)
                if compress_accum:
                    acc, err = GC.accumulate(acc, grads, err)
                else:
                    acc = jax.tree.map(
                        lambda a, g: a + g.astype(a.dtype), acc, grads)
                return (acc, err, loss_sum + loss), None

            from repro.models.shard_hints import constrain_layer_params
            acc0 = jax.tree.map(
                lambda p: jnp.zeros(
                    p.shape, jnp.bfloat16 if compress_accum else jnp.float32),
                params)
            # ZeRO-2: accumulator sharded over "data" on top of the param
            # sharding — per-microbatch gradient reductions lower to
            # reduce-scatters instead of all-reduces (EXPERIMENTS §Perf B3)
            acc0 = constrain_layer_params(acc0, cfg, zero=True)
            err0 = GC.ef_init(params) if compress_accum else acc0
            err0 = constrain_layer_params(err0, cfg, zero=True)
            (acc, _, loss_sum), _ = jax.lax.scan(
                micro_step, (acc0, err0, jnp.zeros((), jnp.float32)),
                micro, length=microbatches)
            grads = jax.tree.map(
                lambda a: a.astype(jnp.float32) / microbatches, acc)
            loss = loss_sum / microbatches
            metrics = {"ce": loss, "aux": jnp.zeros(())}

        params, opt_state, opt_metrics = adamw.update(params, grads,
                                                      opt_state, ocfg)
        metrics = dict(metrics, **opt_metrics, loss=loss)
        return params, opt_state, metrics

    return train_step
