"""Serve a small model with batched requests through the engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import numpy as np

import jax

from repro.configs import get, load_all, reduced
from repro.models import transformer as T
from repro.serve.engine import Engine, Request

load_all()
cfg = reduced(get("gemma3-4b"), tp=2)   # local:global attention family
params = T.init_model(jax.random.PRNGKey(0), cfg)
eng = Engine(cfg, params, max_batch=3, max_seq=64)

reqs = [
    Request(np.array([5, 9, 2, 7], np.int32), max_new_tokens=6),
    Request(np.array([3, 3], np.int32), max_new_tokens=6,
            temperature=0.8),
    Request(np.array([1, 2, 3, 4, 5, 6], np.int32), max_new_tokens=4),
    Request(np.array([11, 13], np.int32), max_new_tokens=5),
]
for i, r in enumerate(eng.generate(reqs)):
    mode = "greedy" if r.temperature == 0 else f"T={r.temperature}"
    print(f"req {i} ({mode}): {list(r.prompt)} → {r.out_tokens}")
print("all requests served (fixed-slot continuous batching, "
      f"{cfg.name})")
