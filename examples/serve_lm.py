"""Serve a mixed-shape, mixed-format request stream through the
shape-bucketed continuous-batching scheduler.

    PYTHONPATH=src python examples/serve_lm.py

Demonstrates: warmup pre-resolves GEMM plans and pre-compiles every
configured bucket, the mixed stream batches into multi-request
microbatches, steady state records ZERO post-warmup recompiles, and the
batched outputs are bit-exact with the unbatched reference.
"""
import dataclasses

import numpy as np

import jax

from repro.configs import get, load_all, reduced
from repro.models import transformer as T
from repro.serve import Engine, Request, ServeConfig

load_all()
cfg = reduced(get("llama3-8b"), tp=2)      # full-attention → "masked" mode
params = T.init_model(jax.random.PRNGKey(0), cfg)
# a second weight variant on another precision format set (same shapes)
alt_cfg = dataclasses.replace(cfg, mp_formats="fp8_e5m2+fp16+fp32")
alt_params = T.init_model(jax.random.PRNGKey(0), alt_cfg)

eng = Engine(cfg, params, ServeConfig(max_batch=3, max_seq=64),
             variants={"fp8_e5m2+fp16+fp32": alt_params})
rep = eng.warmup()
print(f"warmup: {rep.pop('traces')} traces across "
      f"{len(rep)} buckets (plans + executables pre-resolved)")

mixed = [
    Request(np.array([5, 9, 2, 7], np.int32), max_new_tokens=6),
    Request(np.array([3, 3], np.int32), max_new_tokens=6),
    Request(np.array([1, 2, 3, 4, 5, 6], np.int32), max_new_tokens=4,
            fset="fp8_e5m2+fp16+fp32"),
    Request(np.array([11, 13], np.int32), max_new_tokens=5),
    Request(np.array([4, 4, 4], np.int32), max_new_tokens=5,
            fset="fp8_e5m2+fp16+fp32"),
]
eng.generate(mixed)
refs = eng.generate_reference(
    [Request(np.asarray(r.prompt), max_new_tokens=r.max_new_tokens,
             fset=r.fset) for r in mixed])
for i, (r, ref) in enumerate(zip(mixed, refs)):
    tag = "==" if r.out_tokens == ref.out_tokens else "!="
    print(f"req {i} [{r.fset:>20s} {r.bucket:>8s}]: "
          f"{np.asarray(r.prompt).tolist()} → "
          f"{r.out_tokens}  ({tag} unbatched)")

st = eng.stats()
print(f"microbatches={st['microbatches']['total']} "
      f"(multi-request={st['microbatches']['multi_request']}), "
      f"bucket hit rate={st['bucket_hit_rate']:.2f}, "
      f"padding waste={st['padding_waste']:.2f}, "
      f"post-warmup recompiles={st['compile']['post_warmup_recompiles']}")
assert st["compile"]["post_warmup_recompiles"] == 0
assert all(r.out_tokens == ref.out_tokens for r, ref in zip(mixed, refs))
print(f"all requests served, zero recompiles ({cfg.name})")
