"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with every matmul running through the tile-centric mixed-precision GEMM.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses a scaled-down llama-family config (~100M params) on CPU; checkpoints,
injects a fault mid-run, and recovers — demonstrating the full train loop
(data pipeline → MP matmuls → AdamW+ZeRO semantics → checkpoint/restart).
"""
import argparse
import dataclasses

from repro.configs import get, load_all
from repro.core.precision import Policy
from repro.optim import adamw
from repro.runtime.fault import RestartSignal
from repro.train.trainer import TrainerConfig, train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--fault-at", type=int, default=-1)
args = ap.parse_args()

load_all()
# ~100M params: 10 layers, d=640, ff=2560, vocab=32000
cfg = dataclasses.replace(
    get("llama3-8b"),
    name="llama-100m", n_layers=10, d_model=640, n_heads=8, n_kv_heads=4,
    d_ff=2560, vocab=32000, head_dim=80, tp=2, mp_tile=64,
    mp_policy=Policy(kind="ratio", ratio_high=0.25))
print(f"model: {cfg.name}  params ≈ {cfg.param_count()/1e6:.0f}M  "
      f"policy 25D:75S tile {cfg.mp_tile}")

injector = None
if args.fault_at >= 0:
    fired = {}

    def injector(step):
        if step == args.fault_at and not fired:
            fired["x"] = 1
            raise RestartSignal("example-injected fault")

ocfg = adamw.AdamWConfig(lr_peak=3e-4, warmup_steps=20,
                         total_steps=args.steps)
tcfg = TrainerConfig(steps=args.steps, seq_len=args.seq,
                     global_batch=args.batch, microbatches=2,
                     ckpt_dir="/tmp/repro_example_ckpt", ckpt_every=50,
                     log_every=10, fault_injector=injector)
params, opt, hist = train(cfg, ocfg, tcfg)
print(f"final loss {hist[-1]['loss']:.4f} over {len(hist)} recorded steps")
