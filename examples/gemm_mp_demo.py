"""Distributed SUMMA GEMM-MP demo on host devices (paper Algorithm 1 at
cluster scale, shrunk to a 2×2 device grid).

    PYTHONPATH=src python examples/gemm_mp_demo.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MPMatrix, mp_gemm_ref, schedule
from repro.core.precision import PAPER_RATIOS
from repro.core.summa import summa_collective_bytes, summa_mp_gemm
from repro.launch.mesh import make_grid_mesh

P = Q = 2
M = K = N = 128
T = 16
mesh = make_grid_mesh(P, Q)
a = jax.random.normal(jax.random.PRNGKey(0), (M, K))
b = jax.random.normal(jax.random.PRNGKey(1), (K, N))

for name in ("100D:0S", "50D:50S", "0D:100S"):
    pol = PAPER_RATIOS[name]
    pa = schedule.sorted_balanced_map(M // T, K // T, pol, axis=0, groups=P)
    pb = schedule.sorted_balanced_map(K // T, N // T, pol, axis=1, groups=Q)
    pc = schedule.balanced_ratio_map(M // T, N // T, pol, P, Q)
    A = MPMatrix.from_dense(a, pa, T)
    B = MPMatrix.from_dense(b, pb, T)
    C = MPMatrix.from_dense(jnp.zeros((M, N)), pc, T)
    out = summa_mp_gemm(A, B, C, mesh=mesh)
    ref = mp_gemm_ref(A, B, C)
    err = float(jnp.abs(out.to_dense() - ref.to_dense()).max())
    hi = float((pa == 2).mean())
    model = summa_collective_bytes(M, N, K, T, P, Q, hi)
    print(f"{name:8s}: SUMMA vs reference max|Δ| = {err:.2e} | "
          f"panels ship {model['bytes_per_elem_model']:.1f} B/elem "
          f"(receiver-side conversion)")
print("distributed GEMM-MP OK on", mesh)
