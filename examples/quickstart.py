"""Quickstart: the paper's tile-centric mixed-precision GEMM in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import os

# 4 host devices so §6 can demo the distributed path (must be set before
# jax initializes; harmless for the single-device sections)
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=4")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (MPMatrix, Policy, make_map, map_ratio_string,
                        mp_gemm_ref)
from repro.kernels import ops

# --- 1. build tile-heterogeneous operands (paper Fig. 2 style maps) -------
M = K = N = 128
TILE = 16
a = jax.random.normal(jax.random.PRNGKey(0), (M, K))
b = jax.random.normal(jax.random.PRNGKey(1), (K, N))

pol = Policy(kind="ratio", ratio_high=0.5, seed=42)        # "50D:50S"
pa = make_map((M, K), TILE, pol)
pb = make_map((K, N), TILE, pol)
pc = make_map((M, N), TILE, pol)
print("A map:", map_ratio_string(pa), "| storage bytes/elem:",
      MPMatrix.from_dense(a, pa, TILE).storage_bytes() / (M * K))

A = MPMatrix.from_dense(a, pa, TILE)
B = MPMatrix.from_dense(b, pb, TILE)
C = MPMatrix.from_dense(jnp.zeros((M, N)), pc, TILE)

# --- 2. C ← A·B with per-tile precision (Algorithm 1) ---------------------
ref = mp_gemm_ref(A, B, C)                       # jnp reference semantics
out = ops.mp_gemm(A, B, C)                       # Pallas TPU kernel
err = float(jnp.abs(out.to_dense() - ref.to_dense()).max())
print(f"Pallas kernel vs reference: max |Δ| = {err:.2e}")

# --- 3. accuracy follows the HIGH ratio (the paper's dial) ----------------
exact = np.asarray(a, np.float64) @ np.asarray(b, np.float64)
for ratio in (0.0, 0.5, 1.0):
    p = Policy(kind="ratio", ratio_high=ratio)
    Ar = MPMatrix.from_dense(a, make_map((M, K), TILE, p), TILE)
    Br = MPMatrix.from_dense(b, make_map((K, N), TILE, p), TILE)
    Cr = MPMatrix.from_dense(jnp.zeros((M, N)),
                             make_map((M, N), TILE, p), TILE)
    got = np.asarray(mp_gemm_ref(Ar, Br, Cr).to_dense(), np.float64)
    print(f"ratio_high={ratio:.1f}:  max err vs fp64 = "
          f"{np.abs(got - exact).max():.2e}   storage "
          f"{Ar.storage_bytes() / (M*K):.1f} B/elem")

# --- 4. hardware-aware autotuning (the two-line repro.tune API) -----------
# autotune() measures the viable execution paths for this (device, shape,
# precision-map) signature once and persists the winner; mp_matmul() then
# routes every matching call through the cached plan.
from repro.tune import autotune, mp_matmul                     # noqa: E402

plan = autotune(A, B, C)                     # line 1: tune once
out2 = mp_matmul(A, B, C)                    # line 2: dispatch via the plan
err2 = float(jnp.abs(out2.to_dense() - ref.to_dense()).max())
print(f"autotuned plan {plan.key()}: max |Δ| vs reference = {err2:.2e}")

# --- 5. swap the precision formats (the extensible registry) ---------------
# Which concrete formats play the paper's D/S/Q roles is a FormatSet over
# the registry in repro.core.formats — here fp8 e5m2 replaces e4m3 as the Q
# format and fp16 replaces bf16 as the S format.  Any registered format
# (one register_format(...) call) works through maps, layouts, dispatch and
# the cost model; plans are cached per format set.
from repro.core import format_set                              # noqa: E402

fs = format_set("fp8_e5m2", "fp16", "fp32")
pol_q = Policy(kind="ratio", ratio_high=0.25, ratio_low8=0.25, seed=7)
Aq = MPMatrix.from_dense(a, make_map((M, K), TILE, pol_q, fset=fs), TILE, fs)
Bq = MPMatrix.from_dense(b, make_map((K, N), TILE, pol_q, fset=fs), TILE, fs)
outq = mp_matmul(Aq, Bq)
print(f"format set {fs.key()}: storage "
      f"{Aq.storage_bytes() / (M*K):.2f} B/elem, "
      f"out max |val| = {float(jnp.abs(outq.to_dense()).max()):.2f}")

# --- 6. distributed SUMMA on a device grid (multi-device demo) -------------
# The same GEMM on a 2×2 grid: each k-panel is broadcast as one
# storage-precision slab per registered format and upcast receiver-side;
# the local rank-update routes through the distributed plan registry
# (grouped Pallas kernel when a plan is tuned, reference dots otherwise).
# CPU caveat: host "devices" are forced CPU shards and Pallas runs in
# interpret mode, so this demonstrates semantics/wire-bytes, not speed.
from repro.core import schedule                                # noqa: E402
from repro.core.summa import summa_collective_bytes            # noqa: E402
from repro.launch.mesh import make_grid_mesh                   # noqa: E402
from repro.tune import summa_mp_matmul                         # noqa: E402

if jax.device_count() >= 4:
    P = Q = 2
    mesh = make_grid_mesh(P, Q)
    # A/B maps must be sorted-balanced so the per-format slabs have static
    # SPMD shapes; the C map only needs balanced per-shard class counts.
    pa_d = schedule.sorted_balanced_map(M//TILE, K//TILE, pol, 0, P)
    pb_d = schedule.sorted_balanced_map(K//TILE, N//TILE, pol, 1, Q)
    pc_d = schedule.balanced_ratio_map(M//TILE, N//TILE, pol, P, Q)
    Ad = MPMatrix.from_dense(a, pa_d, TILE)
    Bd = MPMatrix.from_dense(b, pb_d, TILE)
    Cd = MPMatrix.from_dense(jnp.zeros((M, N)), pc_d, TILE)
    dist = summa_mp_matmul(Ad, Bd, Cd, mesh=mesh)
    single = mp_matmul(Ad, Bd, Cd)
    errd = float(jnp.abs(dist.to_dense() - single.to_dense()).max())
    wire = summa_collective_bytes(M, N, K, TILE, P, Q,
                                  float((pa_d == Ad.fset.high).mean()))
    print(f"distributed SUMMA {P}x{Q}: max |Δ| vs single-device = "
          f"{errd:.2e}, panels ship "
          f"{wire['bytes_per_elem_model']:.1f} B/elem")
else:  # pragma: no cover — XLA_FLAGS was already set to fewer devices
    print("skipping §6: fewer than 4 host devices")

# --- 7. serve a mixed-shape request stream without recompiles ---------------
# The serving layer keeps the tuned kernels hot under heterogeneous
# traffic: requests are bucketed by (padded length, format-set tag),
# warmup() pre-resolves a GEMM plan and pre-compiles prefill/decode for
# every bucket, and the engine then runs TOKEN-LEVEL continuous batching:
# on-device sampling (no host sync per step), rows that finish early
# retire mid-decode and their slot is refilled from the pending queue,
# and shared prompt prefixes are prefilled once (hash-keyed KV prefix
# cache) — all bit-exact with unbatched decoding (right-padding +
# per-request positions/PRNG streams + a KV visibility mask) and with
# ZERO steady-state recompiles.
import numpy as np                                             # noqa: E402

from repro.configs import get, load_all, reduced               # noqa: E402
from repro.models import transformer as T                      # noqa: E402
from repro.serve import Cluster, Engine, Request, ServeConfig  # noqa: E402

load_all()
cfg = reduced(get("llama3-8b"), tp=2)
params = T.init_model(jax.random.PRNGKey(0), cfg)
eng = Engine(cfg, params, ServeConfig(max_batch=3, max_seq=64))
eng.warmup()                       # plans resolved + buckets compiled here
# mixed lengths AND mixed max_new_tokens: the short generations retire
# early and the freed slots are refilled mid-decode
stream = [Request(np.array(p, np.int32), max_new_tokens=n)
          for p, n in [([1, 2, 3], 2), ([4, 5], 8), ([6, 7, 8, 9, 10], 4),
                       ([3, 1], 8), ([2] * 7, 3), ([5, 6], 4)]]
eng.generate(stream)
st = eng.stats()
print(f"served {st['requests']['served']} mixed-shape requests in "
      f"{st['microbatches']['total']} microbatches "
      f"(multi-request: {st['microbatches']['multi_request']}, "
      f"mid-decode refills: {st['microbatches']['refills']}), "
      f"bucket hit rate {st['bucket_hit_rate']:.2f}, "
      f"post-warmup recompiles: {st['compile']['post_warmup_recompiles']}")
assert st["compile"]["post_warmup_recompiles"] == 0
assert st["microbatches"]["refills"] >= 1  # occupancy held, mixed max_new

# --- 8. adaptive-precision iterative refinement (repro.solve) ---------------
# The precision map as a CONTROL VARIABLE: solve an ill-conditioned system
# starting all-bf16 (0D:100S).  Refinement stalls at bf16 accuracy, the
# residual is attributed to the tiles whose storage rounding caused it,
# those tiles are promoted one role and re-quantized from the exact
# operator, and the solve converges to the fp32 backward-stability bound —
# with the final map still far cheaper than uniform-fp32.  Every plan the
# escalation ladder can need is prefetched: zero mid-solve retunes.
from repro.solve import SolveConfig, graded_spd, rhs_for_solution, solve  # noqa: E402

a_ill = graded_spd(128, cond=1e4, rho=0.8, seed=0)
x_true, b_rhs = rhs_for_solution(a_ill, seed=1)
rep = solve(a_ill, b_rhs, SolveConfig(tile=16, ratio_high=0.0))
print(f"solve: {' -> '.join(rep.ratio_history)} in {rep.sweeps} sweeps "
      f"({rep.escalations} escalations), metric {rep.metric:.2g}, "
      f"storage {rep.storage_bytes}/{rep.uniform_high_bytes} B of "
      f"uniform-HIGH, mid-solve retunes {rep.fresh_resolutions}")
assert rep.converged and rep.fresh_resolutions == 0
assert rep.storage_bytes < rep.uniform_high_bytes

# --- 9. watch the runtime work: repro.obs tracing ---------------------------
# Every layer above emits structured spans/events into repro.obs when
# tracing is on (and is bitwise-identical, zero-file no-op when off — the
# default).  Trace one serve request and one solver escalation; the JSONL
# lines are Chrome trace_event dicts, so the export loads directly in
# Perfetto (https://ui.perfetto.dev) or chrome://tracing.
import json  # noqa: E402
import tempfile  # noqa: E402

from repro import obs  # noqa: E402
from repro.obs.hygiene import validate_events  # noqa: E402
from repro.obs.trace import (export_chrome, read_events,  # noqa: E402
                             span_types)

trace_path = tempfile.mktemp(suffix=".jsonl")
obs.configure(enabled=True, trace_path=trace_path)

# one traced serve request: admit -> microbatch -> prefill -> decode -> retire
eng.generate([Request(np.array([9, 8, 7], np.int32), max_new_tokens=3)])
# one traced solver run: run -> factor -> sweeps (+ escalation instants)
solve(a_ill, b_rhs, SolveConfig(tile=16, ratio_high=0.0))

obs.configure(enabled=False)          # close + flush; back to the no-op
events = read_events(trace_path)
assert validate_events(events) == []  # schema-clean (closed-world cats)
kinds = span_types(events)
print(f"trace: {len(events)} events, span types {kinds}")
assert {"serve.prefill", "serve.decode", "solve.sweep"} <= set(kinds)
chrome = export_chrome(trace_path)    # open this file in Perfetto
print(f"chrome trace: {chrome} "
      f"({len(json.load(open(chrome))['traceEvents'])} trace events)")

# the metrics side needs no tracing: counters are always live
reg = eng.metrics
print(f"engine counters: served={reg.value('serve.requests_served'):.0f} "
      f"decode_steps={reg.value('serve.decode_steps'):.0f} "
      f"latency mean={reg.histogram('serve.request.latency_s').mean:.3f}s")

# --- 10. store MORE bits, or COMPUTE more passes? (repro.split) -------------
# §8 recovered precision by promoting tile *storage*.  The split-accumulation
# subsystem offers the orthogonal move: keep the bytes, decompose each fp32
# operand into low-precision slices (split2_fp16 = two fp16 slices -> 2^-22
# recovered grade) and spend extra low-precision passes instead.  With
# compute_escalation="auto" the solver prices the top escalation rung both
# ways through the tuner's cost model and takes the cheaper route.
from repro.core import format_set as _fs  # noqa: E402

rep_a = solve(a_ill, b_rhs,
              SolveConfig(tile=16, fset=_fs("fp16", "fp32"),
                          compute_escalation="auto"))
print(f"store-vs-compute: model priced store {rep_a.store_cost_s*1e6:.1f}us "
      f"vs split {rep_a.split_cost_s*1e6:.1f}us -> mode={rep_a.compute_mode}")
print(f"  solve: {' -> '.join(rep_a.ratio_history)} in {rep_a.sweeps} "
      f"sweeps, metric {rep_a.metric:.2g}, mid-solve retunes "
      f"{rep_a.fresh_resolutions}")
assert rep_a.converged and rep_a.fresh_resolutions == 0

# --- 11. scale out: a multi-replica cluster behind one front-end ------------
# ServeConfig(replicas=N) puts N data-parallel engines (each optionally
# SUMMA tensor-parallel inside) behind an async admission front-end:
# bounded global queue, least-outstanding-tokens routing with
# bucket/format affinity, and stall re-routing.  Every replica folds the
# same rng_seed, so results are placement-independent — the cluster is
# bit-exact with the single unbatched engine, and long prompts (beyond
# every configured bucket) stream through chunked paged prefill with
# zero recompiles.  Process-wide settings go through repro.configure —
# the facade over the REPRO_* env vars (override > env > default); here
# it turns the obs layer on so the router's serve.route events are live.
import repro  # noqa: E402

repro.configure(obs=True)
cluster = Cluster(cfg, params, ServeConfig(buckets=(4, 8), max_batch=2,
                                           max_seq=64, replicas=2))
cluster.warmup()
wave = [Request(np.array(p, np.int32), max_new_tokens=3)
        for p in ([1, 2, 3], [4, 5], [6, 7, 8, 9], [2] * 11, [3, 1], [9, 9])]
cluster.generate(wave)
refs = cluster.replicas[0].generate_reference(
    [Request(np.asarray(r.prompt), max_new_tokens=3) for r in wave])
cst = cluster.stats()
print(f"cluster: {cst['requests']['served']} requests over "
      f"{cst['healthy']}/{cst['replicas']} replicas "
      f"(placement: {[r.replica for r in wave]}), "
      f"post-warmup recompiles: {cst['post_warmup_recompiles']}")
assert all(r.out_tokens == ref.out_tokens for r, ref in zip(wave, refs))
assert cst["post_warmup_recompiles"] == 0
assert wave[3].bucket.startswith("S16")   # L=11 → chunked 2×8 prefill
repro.configure(obs=False)

# --- 12. int8 serving: the quantized-inference format zoo (repro.quant) -----
# The registry's integer formats store per-tile symmetric-absmax scales
# (int8_pt = 1 B/elem + one fp32 scale per tile) through the
# encode/decode protocol.  quantize_params() rebuilds every ksplit
# weight of a checkpoint under an int set with an ACTIVATION-AWARE map:
# K-blocks multiplying loud input channels keep the float HIGH format
# (their weight rounding is amplified by the activation magnitude), the
# quiet rest drops to int8.  The result is an ordinary params pytree,
# served as an Engine weight variant next to the float weights — same
# buckets, zero extra machinery, zero post-warmup recompiles.
from repro.core.formats import FormatSet      # noqa: E402
from repro.core.layout import KSplitWeight    # noqa: E402
from repro.quant import map_report, quantize_params  # noqa: E402

qset = FormatSet.parse("int8:d")              # aliases: int8_pt + fp32
qparams = quantize_params(params, fset=qset, ratio_high=0.25)
leaves = [w for w in jax.tree_util.tree_leaves(
    qparams, is_leaf=lambda v: isinstance(v, KSplitWeight))
    if isinstance(w, KSplitWeight)]
rep_q = map_report(leaves[0])
qtag = qset.key()
eng_q = Engine(cfg, params, ServeConfig(buckets=(4,), max_batch=2,
                                        max_seq=32), variants={qtag: qparams})
eng_q.warmup()
qreqs = [Request(np.array(p, np.int32), max_new_tokens=3, fset=f)
         for p, f in [([1, 2, 3], "default"), ([4, 5], qtag),
                      ([6, 7, 8, 9], qtag), ([2, 2, 2], "default")]]
eng_q.generate(qreqs)
qst = eng_q.stats()
print(f"int8 serving: weight bytes {rep_q['bytes_vs_fp32']:.2f}x fp32 "
      f"(classes {rep_q['classes']}), served float+{qtag} side by side, "
      f"post-warmup recompiles: {qst['compile']['post_warmup_recompiles']}")
assert qst["compile"]["post_warmup_recompiles"] == 0
assert {r.bucket for r in qreqs} == {"S4/default", f"S4/{qtag}"}
